"""Checkpoint → catalog publishing: the train half of the train→serve
continuous-delta pipeline.

:class:`DeltaPublishCallback` rides :class:`repro.ft.manager.
CheckpointManager`'s ``callbacks`` hook: every k-th completed checkpoint
save is delta-published into a :class:`repro.serve.deploy.
RolloutController` as the next version of a logical function — sharing
the base image's chunks through the CAS (the publish writes only the
pages the fine-tune actually dirtied) — and, by default, immediately
begins a canary so a fraction of live traffic starts serving it.

The callback runs on the manager's save thread (async mode), so
publishing overlaps the next training steps; a publish failure surfaces
on the training thread at the next ``save()``/``wait()`` exactly like a
checkpoint write failure would.
"""
from __future__ import annotations

from typing import Any, Callable, List, Optional

from repro.serve.deploy import RolloutController, VersionRecord

__all__ = ["DeltaPublishCallback"]


def _default_extract(state: Any):
    """Training state is ``{"params": ..., "opt": ...}``; serving
    publishes the params tree."""
    return state["params"]


class DeltaPublishCallback:
    """Publish every ``every``-th checkpoint as a new canary version.

    ``extract`` maps the checkpointed training state to the params tree
    to serve — the hook for parameter-efficient fine-tunes that publish
    only a merged subset of trained weights (smaller dirty set → smaller
    delta).  ``published`` collects the :class:`VersionRecord`\\ s in
    publish order."""

    def __init__(
        self,
        deploy: RolloutController,
        fname: str,
        cfg,
        every: int = 1,
        canary_fraction: float = 0.25,
        auto_canary: bool = True,
        extract: Optional[Callable[[Any], Any]] = None,
        dirpath: Optional[str] = None,
        memory=None,
    ):
        if every < 1:
            raise ValueError(f"every must be >= 1, got {every}")
        self.deploy = deploy
        self.fname = fname
        self.cfg = cfg
        self.every = every
        self.canary_fraction = canary_fraction
        self.auto_canary = auto_canary
        self.extract = extract or _default_extract
        self.dirpath = dirpath
        self.memory = memory
        self.published: List[VersionRecord] = []
        self._seen = 0
        deploy.track(fname)  # fail fast if the base was never published

    def on_checkpoint(self, manager, step: int, state, entry) -> None:
        self._seen += 1
        if (self._seen - 1) % self.every:
            return
        rec = self.deploy.publish_version(
            self.fname, self.cfg, self.extract(state),
            step=step, dirpath=self.dirpath, memory=self.memory,
        )
        if self.auto_canary:
            self.deploy.begin_canary(
                self.fname, rec.version, self.canary_fraction
            )
        self.published.append(rec)
