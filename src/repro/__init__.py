"""SPICE-JAX: serverless model-instance cold starts through runtime
co-design — a JAX/TPU reproduction of "Taming Serverless Cold Starts
Through OS Co-Design" (2025). See DESIGN.md for the paper->TPU mapping."""

__version__ = "0.1.0"
