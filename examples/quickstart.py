"""Quickstart: snapshot a model function to a JIF, tear everything down,
and cold-start it from disk in milliseconds.

    PYTHONPATH=src python examples/quickstart.py
"""
import tempfile

import jax
import numpy as np

from repro.configs import get_config
from repro.models import lm
from repro.serve.engine import ServerlessNode

def main():
    cfg = get_config("qwen1.5-0.5b").reduced()
    params = lm.init_params(cfg, jax.random.PRNGKey(0))

    node = ServerlessNode()
    with tempfile.TemporaryDirectory() as d:
        print("== publish: offline JIF preparation (trace + relocate + trim)")
        spec = node.publish("hello-fn", cfg, params, d)
        print(f"   wrote {spec.jif_path}")

        prompt = np.array([[11, 12, 13, 14]], dtype=np.int32)

        print("== warm up the compile cache (restored via keys, not re-trace)")
        node.invoke("hello-fn", prompt, max_new_tokens=4, mode="spice_sync", cfg=cfg)
        node.evict()

        print("== COLD start: restore from disk, overlap restore & execute")
        r = node.invoke("hello-fn", prompt, max_new_tokens=8, mode="spice", cfg=cfg)
        print(f"   tokens: {r.tokens[0].tolist()}")
        print(f"   ttft:   {r.ttft_s*1e3:.2f} ms   total: {r.total_s*1e3:.2f} ms")
        print(f"   restore stats: {r.stats}")

        print("== baseline comparison (same function, CRIU*-style replay)")
        node.evict()
        rb = node.invoke("hello-fn", prompt, max_new_tokens=8, mode="criu_star", cfg=cfg)
        assert np.array_equal(rb.tokens, r.tokens)
        print(f"   criu*: total {rb.total_s*1e3:.2f} ms "
              f"({rb.total_s/max(r.total_s,1e-9):.2f}x spice)")


if __name__ == "__main__":
    main()
