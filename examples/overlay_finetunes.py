"""Overlay economics: N fine-tunes of one base model, snapshotted with
overlay dedup — storage & restore I/O scale with the *delta*, not the model,
and the node base-image cache serves the shared bytes from RAM.

    PYTHONPATH=src python examples/overlay_finetunes.py
"""
import dataclasses
import tempfile

import jax
import numpy as np

from repro.configs import get_config
from repro.core import BaseImage, NodeImageCache, SpiceRestorer, snapshot
from repro.models import lm
from repro.serve.engine import layerwise_state


def main():
    cfg = get_config("qwen1.5-0.5b").reduced()
    cfg = dataclasses.replace(  # deep enough that delta fractions differ
        cfg, pattern_reps=12, n_layers=12, d_model=256, d_ff=512, head_dim=32
    )
    base_params = lm.init_params(cfg, jax.random.PRNGKey(0))
    base_state = layerwise_state(cfg, base_params)

    cache = NodeImageCache()
    cache.put(BaseImage.from_state("base", base_state))

    with tempfile.TemporaryDirectory() as d:
        print(f"{'finetune':>10} {'total_MB':>9} {'file_MB':>8} {'dedup':>6} {'restore_ms':>10}")
        for i, frac in enumerate([0.05, 0.2, 0.5]):
            # fine-tune the top `frac` of layers
            ft = jax.tree.map(np.asarray, base_state)
            cut = int(len(ft["layers"]) * (1 - frac))
            for li in range(cut, len(ft["layers"])):
                ft["layers"][li] = jax.tree.map(lambda a: a * 1.02, ft["layers"][li])

            path = f"{d}/ft{i}.jif"
            stats = snapshot(ft, path, base=cache.get("base"))

            restorer = SpiceRestorer(node_cache=cache)
            got, _, _, rstats = restorer.restore(path)
            np.testing.assert_allclose(
                got["layers"][-1]["mlp"]["w_down"], ft["layers"][-1]["mlp"]["w_down"]
            )
            print(
                f"{f'{int(frac*100)}%-tuned':>10} "
                f"{stats.total_bytes/1e6:9.1f} {stats.private_bytes/1e6:8.1f} "
                f"{(1-stats.file_fraction)*100:5.1f}% {rstats.total_s*1e3:10.2f}"
            )
        print("\nbase-image cache:", cache.stats)


if __name__ == "__main__":
    main()
