"""Overlay economics: N fine-tunes of one base model, snapshotted as
**delta chains against the parent JIF on disk** — storage & restore I/O
scale with the *delta*, not the model.  Restores run against a COLD node
cache: the parent image is bootstrapped from its file on first use
(``BaseImage.from_jif``) and then serves every sibling's shared bytes from
RAM.

    PYTHONPATH=src python examples/overlay_finetunes.py
"""
import dataclasses
import tempfile

import jax
import numpy as np

from repro.configs import get_config
from repro.core import NodeImageCache, SpiceRestorer, snapshot
from repro.core.lifecycle import parent_cache_key
from repro.models import lm
from repro.serve.engine import layerwise_state


def main():
    cfg = get_config("qwen1.5-0.5b").reduced()
    cfg = dataclasses.replace(  # deep enough that delta fractions differ
        cfg, pattern_reps=12, n_layers=12, d_model=256, d_ff=512, head_dim=32
    )
    base_params = lm.init_params(cfg, jax.random.PRNGKey(0))
    base_state = layerwise_state(cfg, base_params)

    with tempfile.TemporaryDirectory() as d:
        # the parent is just another JIF on disk — no pre-seeded node cache
        parent = f"{d}/base.jif"
        full = snapshot(base_state, parent)
        print(f"base image: {full.total_bytes/1e6:.1f} MB total, "
              f"{full.private_bytes/1e6:.1f} MB private\n")

        cache = NodeImageCache()  # cold: bootstrapped from disk on first restore
        print(f"{'finetune':>10} {'total_MB':>9} {'file_MB':>8} {'dedup':>6} "
              f"{'vs_full':>8} {'restore_ms':>10}")
        for i, frac in enumerate([0.05, 0.2, 0.5]):
            # fine-tune the top `frac` of layers
            ft = jax.tree.map(np.asarray, base_state)
            cut = int(len(ft["layers"]) * (1 - frac))
            for li in range(cut, len(ft["layers"])):
                ft["layers"][li] = jax.tree.map(lambda a: a * 1.02, ft["layers"][li])

            path = f"{d}/ft{i}.jif"
            stats = snapshot(ft, path, parent=parent)

            restorer = SpiceRestorer(node_cache=cache)
            got, _, _, rstats = restorer.restore(path)
            np.testing.assert_allclose(
                got["layers"][-1]["mlp"]["w_down"], ft["layers"][-1]["mlp"]["w_down"]
            )
            print(
                f"{f'{int(frac*100)}%-tuned':>10} "
                f"{stats.total_bytes/1e6:9.1f} {stats.private_bytes/1e6:8.1f} "
                f"{(1-stats.file_fraction)*100:5.1f}% "
                f"{100*stats.private_bytes/max(full.private_bytes,1):7.1f}% "
                f"{rstats.total_s*1e3:10.2f}"
            )
        assert cache.get(parent_cache_key(parent)) is not None
        print("\nbase-image cache:", cache.stats,
              f"resident={cache.total_bytes/1e6:.1f}MB")


if __name__ == "__main__":
    main()
