"""Fault-tolerant training + continuous delivery: train a small LM with
async incremental JIF checkpoints, crash it mid-run, resume bit-exact from
the manifest — then publish the result as a serving function and let a
fine-tune stream new versions straight into the serving tier (canary →
gate → promote → instant rollback).

    PYTHONPATH=src python examples/train_ft.py
"""
import tempfile

import numpy as np

from repro.configs import get_config
from repro.core import ChunkStore
from repro.data.synthetic import DataConfig, SyntheticLM
from repro.ft.manager import CheckpointManager
from repro.ft.publish import DeltaPublishCallback
from repro.serve.cluster import ClusterRouter, FunctionCatalog
from repro.serve.deploy import RolloutController, TokenHealthGate
from repro.serve.node import FixedTTLPolicy, NodeScheduler
from repro.train.loop import LoopConfig, SimulatedFailure, train_loop
from repro.train.steps import TrainStepConfig


def main():
    cfg = get_config("qwen1.5-0.5b").reduced()
    tcfg = TrainStepConfig(remat="dots", num_microbatches=2)
    data = SyntheticLM(DataConfig(vocab_size=cfg.vocab_size, seq_len=32, global_batch=8))

    with tempfile.TemporaryDirectory() as d:
        mgr = CheckpointManager(d, keep=3, anchor_every=2)
        print("== training, failure injected at step 17")
        try:
            train_loop(cfg, tcfg, LoopConfig(steps=30, ckpt_every=5, fail_at_step=17),
                       data, mgr, on_step=lambda s, m: (s % 5 == 0) and print(
                           f"  step {s:3d} loss {m['loss']:.4f}"))
        except SimulatedFailure as e:
            print(f"  !! {e}")
        mgr.wait()
        print(f"== node replaced; resuming from step {mgr.latest_step()} (JIF restore)")
        out = train_loop(cfg, tcfg, LoopConfig(steps=30, ckpt_every=5), data, mgr,
                         on_step=lambda s, m: (s % 5 == 0) and print(
                             f"  step {s:3d} loss {m['loss']:.4f}"))
        print(f"== done: final loss {out['losses'][-1]:.4f}, "
              f"{len(mgr.history)} checkpoints on disk "
              f"({sum(h['bytes_written'] for h in mgr.history)/1e6:.1f} MB written, "
              f"incremental dedup vs anchors)")

        # ---- act 2: the train->serve continuous-delta pipeline ----------
        print("== publishing trained params as serving function 'assistant'")
        store = ChunkStore(f"{d}/cas")
        catalog = FunctionCatalog(chunk_store=store)
        catalog.publish("assistant", cfg, out["params"], d,
                        warm_ttl_s=3600.0, formats=("jif",))
        node = NodeScheduler(registry=catalog.registry,
                             keepalive=FixedTTLPolicy(3600.0))
        router = ClusterRouter(catalog, [node])
        deploy = RolloutController(catalog, seed=0, dirpath=d).attach(router)

        base_params = dict(out["params"])

        def merge(state):
            # parameter-efficient publish: serve the base with just the
            # tuned head grafted on -> the delta pays for the head only
            merged = dict(base_params)
            merged["final_norm"] = state["params"]["final_norm"]
            return merged

        cb = DeltaPublishCallback(deploy, "assistant", cfg, every=1,
                                  canary_fraction=0.5, extract=merge)
        ft_mgr = CheckpointManager(f"{d}/ft", async_save=True, callbacks=[cb])
        print("== fine-tuning; every checkpoint delta-publishes a canary")
        train_loop(cfg, tcfg, LoopConfig(steps=4, ckpt_every=2, seed=1),
                   data, ft_mgr)
        for rec in cb.published:
            print(f"  published {rec.name} (step {rec.step}): "
                  f"{rec.private_bytes/1e3:.0f} KB delta vs "
                  f"{rec.total_bytes/1e6:.1f} MB full image")
        canary = deploy.canary("assistant")
        print(f"== canary {canary.name} taking "
              f"{deploy.lineage('assistant').canary_fraction:.0%} of traffic")
        prompt = np.array([[3, 1, 4, 1, 5, 9]], dtype=np.int32)
        served = [router.invoke("assistant", prompt, max_new_tokens=2,
                                mode="spice", cfg=cfg).function
                  for _ in range(6)]
        print(f"  A/B split served versions: {sorted(set(served))}")
        ok = deploy.evaluate_canary(
            "assistant", prompt, gate=TokenHealthGate(cfg.vocab_size),
            n_probes=2, max_new_tokens=2, cfg=cfg)
        print(f"== gate {'passed -> promoted' if ok else 'failed -> rejected'} "
              f"{canary.name}; stable is now "
              f"v{deploy.current('assistant').version}")
        back = deploy.rollback("assistant")
        print(f"== instant rollback -> v{back.version} "
              f"(pointer repoint, zero new bytes published)")
        print(f"  retired after GC: {deploy.gc_retired('assistant')}")
        store.audit()
        router.close()


if __name__ == "__main__":
    main()
