"""Fault-tolerant training: train a small LM with async incremental JIF
checkpoints, crash it mid-run, and resume bit-exact from the manifest.

    PYTHONPATH=src python examples/train_ft.py
"""
import tempfile

import numpy as np

from repro.configs import get_config
from repro.data.synthetic import DataConfig, SyntheticLM
from repro.ft.manager import CheckpointManager
from repro.train.loop import LoopConfig, SimulatedFailure, train_loop
from repro.train.steps import TrainStepConfig


def main():
    cfg = get_config("qwen1.5-0.5b").reduced()
    tcfg = TrainStepConfig(remat="dots", num_microbatches=2)
    data = SyntheticLM(DataConfig(vocab_size=cfg.vocab_size, seq_len=32, global_batch=8))

    with tempfile.TemporaryDirectory() as d:
        mgr = CheckpointManager(d, keep=3, anchor_every=2)
        print("== training, failure injected at step 17")
        try:
            train_loop(cfg, tcfg, LoopConfig(steps=30, ckpt_every=5, fail_at_step=17),
                       data, mgr, on_step=lambda s, m: (s % 5 == 0) and print(
                           f"  step {s:3d} loss {m['loss']:.4f}"))
        except SimulatedFailure as e:
            print(f"  !! {e}")
        mgr.wait()
        print(f"== node replaced; resuming from step {mgr.latest_step()} (JIF restore)")
        out = train_loop(cfg, tcfg, LoopConfig(steps=30, ckpt_every=5), data, mgr,
                         on_step=lambda s, m: (s % 5 == 0) and print(
                             f"  step {s:3d} loss {m['loss']:.4f}"))
        print(f"== done: final loss {out['losses'][-1]:.4f}, "
              f"{len(mgr.history)} checkpoints on disk "
              f"({sum(h['bytes_written'] for h in mgr.history)/1e6:.1f} MB written, "
              f"incremental dedup vs anchors)")


if __name__ == "__main__":
    main()
