"""End-to-end driver: a serverless node serving BATCHED requests across a
zoo of model functions with aggressive reclamation — every invocation after
an idle gap is a disk cold start, which Spice makes near-warm.

    PYTHONPATH=src python examples/serve_coldstart.py
"""
import tempfile
import time

import jax
import numpy as np

from repro.configs import get_config
from repro.core import BaseImage
from repro.models import lm
from repro.serve.engine import ServerlessNode, layerwise_state

REQUESTS = [  # (function, prompt len) — a bursty multi-tenant trace
    ("chat-a", 8), ("chat-a", 8), ("code-b", 16), ("chat-a", 8),
    ("ssm-c", 8), ("code-b", 16), ("chat-a", 8), ("ssm-c", 8),
]


def main():
    node = ServerlessNode()
    with tempfile.TemporaryDirectory() as d:
        # three functions; two share one base image (a "Python+AI pool")
        base_cfg = get_config("qwen1.5-0.5b").reduced()
        base_params = lm.init_params(base_cfg, jax.random.PRNGKey(1))
        node.node_cache.put(
            BaseImage.from_state("pool-base", layerwise_state(base_cfg, base_params))
        )
        ft = jax.tree.map(lambda a: a, base_params)
        ft["final_norm"] = ft["final_norm"] * 1.01
        node.publish("chat-a", base_cfg, base_params, d, base_name="pool-base")
        node.publish("code-b", base_cfg, ft, d, base_name="pool-base")

        ssm_cfg = get_config("mamba2-780m").reduced()
        node.publish("ssm-c", ssm_cfg, lm.init_params(ssm_cfg, jax.random.PRNGKey(2)), d)

        cfgs = {"chat-a": base_cfg, "code-b": base_cfg, "ssm-c": ssm_cfg}
        # compile-cache warmup per arch
        for f, cfg in cfgs.items():
            node.invoke(f, np.ones((1, 4), np.int32), 2, mode="spice_sync", cfg=cfg)

        print(f"{'req':>3} {'function':>8} {'start':>6} {'ttft_ms':>9} {'total_ms':>9}")
        for i, (fname, plen) in enumerate(REQUESTS):
            node.evict()  # aggressive reclamation: idle instances are freed
            prompt = np.tile(np.arange(1, plen + 1, dtype=np.int32), (2, 1))
            r = node.invoke(fname, prompt, max_new_tokens=4, mode="spice",
                            cfg=cfgs[fname])
            print(f"{i:>3} {fname:>8} {'cold':>6} {r.ttft_s*1e3:9.2f} {r.total_s*1e3:9.2f}")

        print("\nnode cache:", node.node_cache.stats)
        print("buffer pool:", node.pool.stats)


if __name__ == "__main__":
    main()
